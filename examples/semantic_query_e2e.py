"""End-to-end semantic query execution (paper §4.3): build the full stack,
plan multi-filter queries with every estimator, execute the cascades, and
report overhead vs the zero-latency oracle.

    PYTHONPATH=src python examples/semantic_query_e2e.py [--dataset ecommerce]

(This is the example-sized version of benchmarks/fig4_end_to_end.py; the
serving driver `python -m repro.launch.serve` exposes the same flow as a CLI.)
"""

import argparse
import pathlib
import sys

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.common import dataset_stack
from repro.core.optimizer import execute_cascade, generate_queries, plan_query


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="wildlife")
    ap.add_argument("--n-queries", type=int, default=6)
    args = ap.parse_args()

    stack = dataset_stack(args.dataset)
    corpus = stack["corpus"]
    queries = generate_queries(corpus, n_queries=args.n_queries, n_filters=3,
                               seed=0)
    print(f"{args.dataset}: {len(queries)} queries x 3 filters, "
          f"N={len(corpus.images)} images\n")

    totals = {}
    for q in queries:
        base = execute_cascade(corpus, plan_query(q, stack["oracle"]), seed=0)
        for name in ("specificity", "kvbatch", "ensemble"):
            res = execute_cascade(corpus, plan_query(q, stack[name], seed=0),
                                  seed=0)
            totals.setdefault(name, []).append(res.total_s - base.total_s)

    print(f"{'method':>12s} {'mean overhead vs oracle':>26s}")
    for name, os_ in totals.items():
        print(f"{name:>12s} {np.mean(os_):>20.2f}s ± {np.std(os_):.2f}")


if __name__ == "__main__":
    main()

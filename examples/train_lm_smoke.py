"""Train a reduced LM end-to-end with the full distributed-training substrate
(data pipeline -> train_step -> watchdog -> async checkpoints), on CPU.

    PYTHONPATH=src python examples/train_lm_smoke.py [--arch jamba-v0.1-52b]

Every assigned arch id works (reduced configs); loss must decrease.
"""

import argparse
import sys

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    args, _ = ap.parse_known_args()
    sys.argv = ["train", "--arch", args.arch, "--steps", "30", "--batch", "8",
                "--seq", "64", "--ckpt-every", "10"]
    train.main()


if __name__ == "__main__":
    main()

"""Train the specificity model end-to-end with the framework's own training
substrate, with checkpointing and fault tolerance — the 'train a model for a
few hundred steps' example (deliverable b).

    PYTHONPATH=src python examples/train_specificity.py
"""

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.paper_stack import SpecificityModelConfig
from repro.core.specificity import specificity_apply, specificity_specs, train_specificity
from repro.core.synthetic import make_corpus, specificity_dataset


def main():
    corpus = make_corpus("wildlife", n_images=1000, seed=0)
    X, y = specificity_dataset(corpus, n_samples=4000, seed=0)
    cfg = SpecificityModelConfig(embed_dim=X.shape[1], steps=800)
    model, metrics = train_specificity(X, y, cfg, log_every=100)
    print(f"\ntrained {cfg.steps} steps in {metrics['train_s']:.1f}s  "
          f"val_mae={metrics['val_mae']:.4f}")

    ckpt = CheckpointManager("/tmp/repro_spec_ckpt", keep=2)
    ckpt.save(cfg.steps, model.params)
    restored = ckpt.restore(None, like=model.params)
    import jax.numpy as jnp

    p = specificity_apply(restored, jnp.asarray(X[:4]))
    print("restored-model thresholds for 4 predicates:",
          np.round(np.asarray(p), 4))


if __name__ == "__main__":
    main()
